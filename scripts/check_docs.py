#!/usr/bin/env python
"""Docs gate: internal anchors, referenced paths, and §-references resolve.

Run by scripts/ci.sh on every pass. Three checks, all cheap and offline:

1. **Markdown links** in the tracked docs (README.md, DESIGN.md,
   ROADMAP.md, benchmarks/README.md): ``[text](target)`` where target is
   - ``#anchor``          -> a heading in the same file must slugify to it;
   - ``path``             -> the file/dir must exist relative to the doc;
   - ``path#anchor``      -> both of the above, anchor checked in ``path``.
   ``http(s)://`` links are skipped (no network in CI).
2. **DESIGN.md § references from code**: every ``DESIGN.md §N`` mentioned
   in a docstring/comment under src/, benchmarks/, tests/, scripts/ must
   have a matching ``## §N`` heading — docstrings and the design doc
   drift independently otherwise (the ISSUE-5 failure mode this gate
   exists for).
3. **Backtick path references** in the docs that look like repo paths
   (contain a ``/`` and end in a known extension) must exist.

Exit code 0 on success; 1 with a listing of every broken reference.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ["README.md", "DESIGN.md", "ROADMAP.md", "benchmarks/README.md"]
CODE_DIRS = ["src", "benchmarks", "tests", "scripts", "examples"]
PATH_EXTS = (".py", ".md", ".sh", ".json")

LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.M)
SECTION_REF_RE = re.compile(r"DESIGN\.md\s+§(\d+)")
BACKTICK_PATH_RE = re.compile(r"`([\w./-]+/[\w.-]+)`")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug (ASCII-conservative: the docs only use
    anchors this slugger can produce)."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def headings_of(path: str) -> set[str]:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    return {slugify(m.group(2)) for m in HEADING_RE.finditer(text)}


def check_doc_links(doc: str, errors: list[str]) -> None:
    doc_path = os.path.join(REPO, doc)
    doc_dir = os.path.dirname(doc_path)
    with open(doc_path, encoding="utf-8") as f:
        text = f.read()
    anchors = headings_of(doc_path)
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, frag = target.partition("#")
        if not path_part:                       # same-file anchor
            if frag not in anchors:
                errors.append(f"{doc}: broken anchor #{frag}")
            continue
        ref = os.path.normpath(os.path.join(doc_dir, path_part))
        if not os.path.exists(ref):
            errors.append(f"{doc}: broken path link {target}")
            continue
        if frag and ref.endswith(".md"):
            if frag not in headings_of(ref):
                errors.append(f"{doc}: broken anchor {target}")


def check_backtick_paths(doc: str, errors: list[str]) -> None:
    doc_path = os.path.join(REPO, doc)
    with open(doc_path, encoding="utf-8") as f:
        text = f.read()
    for ref in BACKTICK_PATH_RE.findall(text):
        if not ref.endswith(PATH_EXTS) or ref.startswith("/"):
            continue                             # not a repo-path claim
        if ref.startswith("BENCH_"):
            continue                             # benchmark artifacts
        cands = [os.path.join(REPO, ref),
                 os.path.join(os.path.dirname(doc_path), ref),
                 os.path.join(REPO, "src", "repro", ref)]
        if not any(os.path.exists(c) for c in cands):
            errors.append(f"{doc}: backtick path `{ref}` does not exist")


def check_design_sections(errors: list[str]) -> None:
    with open(os.path.join(REPO, "DESIGN.md"), encoding="utf-8") as f:
        design = f.read()
    sections = set(re.findall(r"^##\s+§(\d+)", design, re.M))
    refs: dict[str, list[str]] = {}
    for d in CODE_DIRS:
        for root, _, files in os.walk(os.path.join(REPO, d)):
            for fn in files:
                if not fn.endswith((".py", ".sh", ".md")):
                    continue
                path = os.path.join(root, fn)
                with open(path, encoding="utf-8", errors="ignore") as f:
                    for sec in SECTION_REF_RE.findall(f.read()):
                        refs.setdefault(sec, []).append(
                            os.path.relpath(path, REPO))
    for sec, files in sorted(refs.items()):
        if sec not in sections:
            errors.append(
                f"DESIGN.md has no '## §{sec}' heading but it is referenced "
                f"from: {', '.join(sorted(set(files))[:5])}")


def main() -> int:
    errors: list[str] = []
    for doc in DOCS:
        if not os.path.exists(os.path.join(REPO, doc)):
            errors.append(f"missing doc: {doc}")
            continue
        check_doc_links(doc, errors)
        check_backtick_paths(doc, errors)
    check_design_sections(errors)
    if errors:
        print("docs gate FAILED:")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"docs gate OK ({len(DOCS)} docs, anchors/paths/§-refs resolve)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
