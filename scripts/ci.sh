#!/usr/bin/env bash
# CI gate: tier-1 tests + the rulebook-execution smoke benchmark.
#
#   scripts/ci.sh            # full tier-1 suite, then the smoke gate
#   scripts/ci.sh --fast     # -x (stop at first failure) for quick loops
#
# The smoke benchmark (benchmarks/run.py --smoke) runs the fused
# output-stationary kernel in Pallas interpret mode on tiny shapes and
# exits nonzero on parity drift against the XLA rulebook oracle or on any
# fusion-audit regression (materialized gather / post-kernel scatter-add /
# partial-product array reappearing in the fused path's jaxpr).
#
# The smoke run also carries the octent search-parity gate
# (search_speedup.run_smoke, standalone: benchmarks/search_speedup.py
# --smoke), exercising the map-search kernel under the Pallas interpreter
# on every run: bit-exact kmap parity vs the host hash oracle, zero XLA
# sort ops in the plan build, and no HBM query tensor on the fused path;
# then the 8-device host-CPU sharded gate
# (search_speedup.run_smoke_sharded): sharded-vs-single kmap parity on
# one small cloud over 2/8-way meshes plus the jaxpr audit that no shard
# ever holds the full voxel table; and finally the cross-step cache gate
# (cache_model.run_smoke): tier byte-model sanity plus a two-step
# MinkUNet train loop over a re-allocated identical cloud asserting the
# map-search count stays flat (DESIGN.md §10); and the robustness gate
# (chaos.run_smoke): the same train loop under a deterministic fault
# schedule hitting every injection site must finish bit-identical to
# the clean run, a starved block table must recover via overflow-
# adaptive replanning, guard overhead must stay within the 2 %
# clean-path budget, and the cloud sanitizer must catch every failure
# class (DESIGN.md §11); and the serving gate (serve_replay.run_smoke,
# deterministic adversarial replay through the continuous-batching
# engine with faults at every serving site incl. admit/batch): zero
# cross-request contamination — every clean request's logits digest
# bit-identical to the fault-free replay, only the victim isolated —
# exact shed/rejected/isolated/degraded accounting against
# RuntimeHealth, bounded shedding (only the expired-deadline requests),
# and one compiled executable per padding bucket (DESIGN.md §12); and
# the persistence gate (restart_replay.run_smoke): SIGKILL worker
# subprocesses mid-checkpoint / mid-snapshot / mid-serve-tick via the
# scheduled "kill" fault site, restart them over the surviving dirs,
# and assert bit-identical recovery against the uninterrupted
# reference, zero map searches on warm-restarted geometries, clean
# cold starts (counted persist.dropped, never a crash) from truncated /
# bit-flipped / version-bumped / foreign / salt-mismatched snapshots,
# journaled in-flight serve requests re-queued exactly once, and typed
# "restart" sheds for the ones whose deadline died with the process
# (DESIGN.md §13); and the SPAC gate (sparsity_saving.run_smoke): a
# tiny octent-engine plan with deterministically killed tiles and Cin
# blocks must show a measured MAC reduction above the floor with the
# grain ordering macs_block < macs_tile < macs_geo, spac-on forward
# bit-identical to spac-off under both interpret and ref impls, and
# the fused BN/ReLU epilogue matching the unfused math with its
# emitted ActSparsity exactly a fresh sweep of its own output
# (DESIGN.md §14) — results in BENCH_spac.json; and the streaming gate
# (stream_replay.run_smoke): a low-turnover moving-sensor replay
# through two StreamSessions — delta path vs from-scratch — must stay
# bit-identical per frame at the QueryTable, kmap, and forward-logit
# level, search strictly fewer rows than scratch on every post-warmup
# frame and under 0.5x overall, and cost zero stage-2 query rows on a
# byte-identical repeated frame (DESIGN.md §15) — results in
# BENCH_stream.json.
#
# The docs gate (scripts/check_docs.py) keeps README/DESIGN/ROADMAP and
# benchmarks/README honest: internal anchors, referenced file paths, and
# every "DESIGN.md §N" docstring reference must resolve.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-x)
fi

echo "== docs gate =="
python scripts/check_docs.py

echo "== tier-1 tests =="
python -m pytest "${PYTEST_ARGS[@]}"

echo "== rulebook + search + cache + robustness + serving + persistence + spac + streaming smoke gates =="
python -m benchmarks.run --smoke

echo "CI OK"
